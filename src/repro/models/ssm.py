"""Mamba-2 (SSD — state-space duality) blocks, chunked matmul formulation.

The SSD algorithm is the TPU-friendly form of Mamba-2: the sequence is split
into chunks; within a chunk the recurrence is computed as a (small) quadratic
attention-like matmul, across chunks a lax.scan carries the [H, N, P] state.
This keeps every op MXU-shaped, exactly the adaptation the assigned
architectures need on TPU (DESIGN.md §4).

Decode is the O(1)-per-token recurrent step — the reason mamba2/zamba2 are
the two archs that run the long_500k shape.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common as cm


@dataclasses.dataclass(frozen=True)
class Mamba2Config:
    name: str
    n_layers: int
    d_model: int
    d_state: int  # N
    vocab: int
    head_dim: int = 64  # P
    expand: int = 2
    n_groups: int = 1  # G (B/C groups)
    conv_width: int = 4
    chunk: int = 128
    norm_eps: float = 1e-6
    tie_embed: bool = True
    remat: str = "full"
    sub_quadratic: bool = True

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        assert self.d_inner % self.head_dim == 0
        return self.d_inner // self.head_dim

    @property
    def conv_channels(self) -> int:
        return self.d_inner + 2 * self.n_groups * self.d_state

    def param_count(self) -> int:
        d, di, g, n, h = (
            self.d_model,
            self.d_inner,
            self.n_groups,
            self.d_state,
            self.n_heads,
        )
        per_layer = (
            d * (2 * di + 2 * g * n + h)  # in_proj
            + self.conv_width * self.conv_channels
            + self.conv_channels
            + 3 * h  # dt_bias, A_log, D
            + di  # gate norm
            + di * d  # out_proj
            + d  # ln
        )
        return int(self.n_layers * per_layer + self.vocab * d + d)

    def active_param_count(self) -> int:
        return self.param_count()


# ------------------------------------------------------------------ params
def init_mamba_layer(key, cfg: Mamba2Config):
    ks = cm.keygen(key)
    d, di, h = cfg.d_model, cfg.d_inner, cfg.n_heads
    gn = cfg.n_groups * cfg.d_state
    return {
        "ln": jnp.zeros((d,), jnp.float32),
        "in_proj": cm.ninit(next(ks), (d, 2 * di + 2 * gn + h), d),
        "conv_w": cm.ninit(next(ks), (cfg.conv_width, cfg.conv_channels), cfg.conv_width),
        "conv_b": jnp.zeros((cfg.conv_channels,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "A_log": jnp.zeros((h,), jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "gate_norm": jnp.zeros((di,), jnp.float32),
        "out_proj": cm.ninit(next(ks), (di, d), di),
    }


def mamba_layer_logical(cfg: Mamba2Config):
    return {
        "ln": ("embed",),
        "in_proj": ("embed", "ssm_heads"),
        "conv_w": ("conv", "ssm_heads"),
        "conv_b": ("ssm_heads",),
        "dt_bias": ("ssm_heads",),
        "A_log": ("ssm_heads",),
        "D": ("ssm_heads",),
        "gate_norm": ("ssm_heads",),
        "out_proj": ("ssm_heads", "embed"),
    }


def init_params(key, cfg: Mamba2Config):
    ks = cm.keygen(key)
    layers = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *(init_mamba_layer(next(ks), cfg) for _ in range(cfg.n_layers)),
    )
    return {
        "embed": cm.ninit(next(ks), (cfg.vocab, cfg.d_model), cfg.d_model),
        "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
        "layers": layers,
    }


def param_logical(cfg: Mamba2Config):
    spec = jax.tree.map(
        lambda t: ("layers",) + t,
        mamba_layer_logical(cfg),
        is_leaf=lambda x: isinstance(x, tuple),
    )
    return {"embed": ("vocab", "embed"), "final_norm": ("embed",), "layers": spec}


# ----------------------------------------------------------------- core SSD
def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array, state=None):
    """Depthwise causal conv over seq. x [B, S, C], w [W, C]. If `state`
    ([B, W-1, C]) is given, runs in streaming mode and returns new state."""
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # [B, S+W-1, C]
    out = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(width)
    )
    out = out + b[None, None, :]
    new_state = xp[:, -(width - 1) :, :]
    return jax.nn.silu(out.astype(jnp.float32)).astype(x.dtype), new_state


def _split_proj(h: jax.Array, cfg: Mamba2Config):
    di, gn, nh = cfg.d_inner, cfg.n_groups * cfg.d_state, cfg.n_heads
    z = h[..., :di]
    xbc = h[..., di : di + di + 2 * gn]
    dt = h[..., di + di + 2 * gn :]
    assert dt.shape[-1] == nh
    return z, xbc, dt


def ssd_chunked(
    x: jax.Array,  # [B, S, H, P]
    dt: jax.Array,  # [B, S, H] (post-softplus)
    A: jax.Array,  # [H] (negative)
    B_in: jax.Array,  # [B, S, G, N]
    C_in: jax.Array,  # [B, S, G, N]
    chunk: int,
    init_state: Optional[jax.Array] = None,  # [B, H, N, P]
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y [B, S, H, P], final_state [B, H, N, P])."""
    b, s, h, p = x.shape
    g, n = B_in.shape[2], B_in.shape[3]
    hg = h // g
    q = min(chunk, s)
    assert s % q == 0, (s, q)
    nc = s // q

    xr = x.reshape(b, nc, q, h, p)
    dtr = dt.reshape(b, nc, q, h)
    Br = B_in.reshape(b, nc, q, g, n)
    Cr = C_in.reshape(b, nc, q, g, n)
    causal = jnp.tril(jnp.ones((q, q), bool))

    def chunk_step(state, inp):
        xb, dtb, Bb, Cb = inp  # [b,q,h,p], [b,q,h], [b,q,g,n] x2
        a = dtb * A[None, None, :]  # [b,q,h] log-decays (<= 0)
        cum = jnp.cumsum(a, axis=1)  # inclusive
        total = cum[:, -1, :]  # [b,h]
        # intra-chunk (quadratic in q — the "attention dual")
        L = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])  # [b,qi,qj,h]
        L = jnp.where(causal[None, :, :, None], L, 0.0)
        scores = jnp.einsum("bqgn,bkgn->bqkg", Cb, Bb)  # [b,qi,qj,g]
        scores = jnp.repeat(scores, hg, axis=-1)  # broadcast groups->heads
        xdt = xb * dtb[..., None].astype(xb.dtype)
        y = jnp.einsum("bqkh,bkhp->bqhp", (scores * L).astype(x.dtype), xdt)
        # inter-chunk: contribution of carried state
        Ch = jnp.repeat(Cb, hg, axis=2).reshape(b, q, h, n)
        y = y + jnp.einsum(
            "bqhn,bhnp->bqhp", (Ch * jnp.exp(cum)[..., None]).astype(x.dtype), state
        ).astype(y.dtype)
        # state update
        decay_to_end = jnp.exp(total[:, None, :] - cum)  # [b,q,h]
        Bh = jnp.repeat(Bb, hg, axis=2).reshape(b, q, h, n)
        state_new = jnp.exp(total)[..., None, None] * state + jnp.einsum(
            "bqhn,bqhp->bhnp", (Bh * (decay_to_end * dtb)[..., None]).astype(x.dtype), xb
        )
        return state_new.astype(jnp.float32), y

    state0 = (
        init_state
        if init_state is not None
        else jnp.zeros((b, h, n, p), jnp.float32)
    )
    final_state, ys = jax.lax.scan(
        chunk_step,
        state0,
        (
            jnp.moveaxis(xr, 1, 0),
            jnp.moveaxis(dtr, 1, 0),
            jnp.moveaxis(Br, 1, 0),
            jnp.moveaxis(Cr, 1, 0),
        ),
    )
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, h, p)
    return y, final_state


def mamba_block(x: jax.Array, p: dict, cfg: Mamba2Config):
    """Full Mamba-2 block with pre-norm and residual. x [B, S, d]."""
    b, s, d = x.shape
    h = cm.rms_norm(x, p["ln"], cfg.norm_eps)
    proj = h @ p["in_proj"]
    z, xbc, dt = _split_proj(proj, cfg)
    xbc, _ = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    di, gn = cfg.d_inner, cfg.n_groups * cfg.d_state
    xs = xbc[..., :di].reshape(b, s, cfg.n_heads, cfg.head_dim)
    B_in = xbc[..., di : di + gn].reshape(b, s, cfg.n_groups, cfg.d_state)
    C_in = xbc[..., di + gn :].reshape(b, s, cfg.n_groups, cfg.d_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, _ = ssd_chunked(xs, dt, A, B_in, C_in, cfg.chunk)
    y = y + xs * p["D"][None, None, :, None].astype(xs.dtype)
    y = y.reshape(b, s, di)
    y = cm.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(z.dtype),
                    p["gate_norm"], cfg.norm_eps)
    return x + (y @ p["out_proj"]).astype(x.dtype)


def mamba_decode_block(x, p, cfg: Mamba2Config, ssm_state, conv_state):
    """Single-token recurrent step. x [B, 1, d]. Returns (x, ssm', conv')."""
    b = x.shape[0]
    h = cm.rms_norm(x, p["ln"], cfg.norm_eps)
    proj = h @ p["in_proj"]
    z, xbc, dt = _split_proj(proj, cfg)
    xbc, conv_state = _causal_conv(xbc, p["conv_w"], p["conv_b"], state=conv_state)
    di, gn = cfg.d_inner, cfg.n_groups * cfg.d_state
    xs = xbc[:, 0, :di].reshape(b, cfg.n_heads, cfg.head_dim)
    B_in = xbc[:, 0, di : di + gn].reshape(b, cfg.n_groups, cfg.d_state)
    C_in = xbc[:, 0, di + gn :].reshape(b, cfg.n_groups, cfg.d_state)
    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # [B, H]
    A = -jnp.exp(p["A_log"])
    hg = cfg.n_heads // cfg.n_groups
    Bh = jnp.repeat(B_in, hg, axis=1)  # [B, H, N]
    Ch = jnp.repeat(C_in, hg, axis=1)
    decay = jnp.exp(dt1 * A[None, :])  # [B, H]
    upd = (dt1[..., None] * Bh.astype(jnp.float32))[..., :, None] * xs.astype(
        jnp.float32
    )[..., None, :]
    ssm_state = decay[..., None, None] * ssm_state + upd  # [B, H, N, P]
    y = jnp.einsum("bhn,bhnp->bhp", Ch.astype(jnp.float32), ssm_state)
    y = y.astype(xs.dtype) + xs * p["D"][None, :, None].astype(xs.dtype)
    y = y.reshape(b, 1, di)
    y = cm.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(z.dtype),
                    p["gate_norm"], cfg.norm_eps)
    return x + (y @ p["out_proj"]).astype(x.dtype), ssm_state, conv_state


# ------------------------------------------------------------- full LM defs
def forward(params, tokens, cfg: Mamba2Config):
    x = cm.embed(tokens, params["embed"])

    def body(x, lp):
        return mamba_block(x, lp, cfg), None

    body = (
        body
        if cfg.remat == "none"
        else (
            jax.checkpoint(body)
            if cfg.remat == "full"
            else jax.checkpoint(
                body,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            )
        )
    )
    x, _ = jax.lax.scan(body, x, params["layers"])
    x = cm.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, jnp.zeros((), jnp.float32)


def loss_fn(params, batch, cfg: Mamba2Config):
    feats, aux = forward(params, batch["tokens"], cfg)
    return cm.cross_entropy_chunked(feats, params["embed"], batch["labels"]) + aux


def prefill_logits(params, batch, cfg: Mamba2Config):
    feats, _ = forward(params, batch["tokens"], cfg)
    return cm.last_token_logits(feats, params["embed"])


def init_cache_shape(cfg: Mamba2Config, batch: int, cache_len: int):
    del cache_len  # state size is O(1) in context length — the whole point
    return {
        "ssm": jax.ShapeDtypeStruct(
            (cfg.n_layers, batch, cfg.n_heads, cfg.d_state, cfg.head_dim), jnp.float32
        ),
        "conv": jax.ShapeDtypeStruct(
            (cfg.n_layers, batch, cfg.conv_width - 1, cfg.conv_channels),
            cm.DEFAULT_DTYPE,
        ),
    }


def cache_logical(cfg: Mamba2Config):
    return {
        "ssm": ("layers", "batch", "ssm_heads", "ssm_state", "head_dim"),
        "conv": ("layers", "batch", "conv", "ssm_heads"),
    }


def decode_step(params, cache, tokens, pos, cfg: Mamba2Config):
    x = cm.embed(tokens, params["embed"])

    def body(x, inp):
        lp, ssm, conv = inp
        x, ssm, conv = mamba_decode_block(x, lp, cfg, ssm, conv)
        return x, (ssm, conv)

    x, (ssm, conv) = jax.lax.scan(body, x, (params["layers"], cache["ssm"], cache["conv"]))
    x = cm.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = cm.unembed(x, params["embed"])
    return logits, {"ssm": ssm, "conv": conv}
