"""Logical-axis sharding rules (MaxText-style, reduced to what we need).

Every parameter/activation carries a tuple of logical axis names; the rules
map them to mesh axes. The same model code then lowers on the single-pod
(16x16 "data","model") and multi-pod (2x16x16 "pod","data","model") meshes.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> mesh axis (or tuple of mesh axes)
BASE_RULES = {
    "batch": ("pod", "data"),  # data parallel over pod x data
    "seq": None,  # sequence kept unsharded by default (SP is a perf knob)
    "seq_shard": ("pod", "data"),  # sequence sharding for decode_* KV caches
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "ffn": "model",
    "vocab": "model",
    "experts": "model",
    "expert_ffn": None,
    "layers": None,
    "conv": None,
    "ssm_state": None,
    "ssm_heads": "model",
    "frames": None,
    "patches": None,
}


def rules_for_mesh(mesh: Mesh, overrides: dict | None = None) -> dict:
    """Drop mesh axes that do not exist (e.g. 'pod' on the single-pod mesh)."""
    names = set(mesh.axis_names)
    out = {}
    rules = dict(BASE_RULES)
    if overrides:
        rules.update(overrides)
    for k, v in rules.items():
        if v is None:
            out[k] = None
        elif isinstance(v, tuple):
            kept = tuple(a for a in v if a in names)
            out[k] = kept if kept else None
        else:
            out[k] = v if v in names else None
    return out


def pspec(logical: Tuple[Optional[str], ...], rules: dict) -> P:
    """Map a tuple of logical axis names to a PartitionSpec."""
    return P(*(rules[a] if a is not None else None for a in logical))


def shardings(logical_tree, mesh: Mesh, rules: dict | None = None):
    """Map a pytree of logical-axis tuples to NamedShardings."""
    rules = rules or rules_for_mesh(mesh)
    return jax.tree.map(
        lambda spec: NamedSharding(mesh, pspec(spec, rules)),
        logical_tree,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(e, (str, type(None))) for e in x),
    )


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
