"""Shared benchmark harness: best-of-N timing + the gate-compatible artifact.

Every benchmark that emits its JSON through `emit_artifact` is
regression-gate compatible BY CONSTRUCTION: the envelope (schema
`bench-artifact/v1`) is exactly what `tests/check_bench_regression.py`
consumes when the nightly job diffs fresh artifacts against the committed
baselines under `experiments/bench/baselines/`.

Envelope::

    {
      "benchmark": "<name>",
      "schema": "bench-artifact/v1",
      "meta":   {...},               # free-form run parameters (not gated)
      "cells":  {"<key>": {"wall_s": <s>, ...}},   # wall_s gated at +25%
      "parity": {"<key>": <value>},  # gated at EXACT equality
      ...                            # legacy fields ride along untouched
    }

Gate semantics: a cell whose fresh `wall_s` exceeds the baseline's by more
than the threshold (default 25%) is a wall-clock regression; any `parity`
entry that differs AT ALL is a parity drift. Parity values must therefore be
deterministic by construction (e.g. `simulations` under a fixed wave budget,
scenario statuses) — never wall-clock-derived numbers.
"""

from __future__ import annotations

import time
from pathlib import Path
from typing import Callable, Dict, Optional, Tuple

from common import RESULTS_DIR, render_table, save_result  # noqa: F401

SCHEMA = "bench-artifact/v1"

#: default wall-clock regression threshold the nightly gate applies
WALL_REGRESSION_THRESHOLD = 0.25


def best_of(fn: Callable, *args, reps: int = 3, warmup: int = 1) -> Tuple:
    """Best-of-`reps` wall time of `fn(*args)` after `warmup` untimed calls.

    Single-run noise on these workloads (~5-10% between identical runs)
    would swamp exactly the cost deltas the nightly artifacts track, so
    every harnessed benchmark times best-of-N with compile/warmup excluded.
    Returns `(last_result, best_seconds)`.
    """
    result = None
    for _ in range(max(0, warmup)):
        result = fn(*args)
    best = None
    for _ in range(max(1, reps)):
        t0 = time.perf_counter()
        result = fn(*args)
        dt = time.perf_counter() - t0
        best = dt if best is None else min(best, dt)
    return result, best


def roofline_fields(
    model: str,
    days: int,
    simulations: float,
    wall_s: float,
    summary=None,
    distance: str = "euclidean",
    schedule=None,
) -> Dict[str, float]:
    """Per-cell roofline instrumentation for the bench envelope.

    Returns `achieved_flops` / `achieved_bytes_per_s` /
    `arithmetic_intensity` / `roofline_efficiency` from the analytic cost
    model (repro.core.tuning.cost_model) at the cell's measured
    (simulations, wall clock). The regression gate tracks
    `roofline_efficiency` for drift alongside `wall_s`. Cells with zero
    simulations (skipped scenarios) return {} so the gate never baselines a
    meaningless efficiency.
    """
    if not simulations or not wall_s or wall_s <= 0:
        return {}
    from repro.core.tuning import bench_cell_metrics

    return bench_cell_metrics(
        model, days, simulations, wall_s,
        summary=summary, distance=distance, schedule=schedule,
    )


def emit_artifact(
    name: str,
    *,
    cells: Dict[str, Dict],
    parity: Optional[Dict] = None,
    meta: Optional[Dict] = None,
    extra: Optional[Dict] = None,
) -> Path:
    """Write the gate-compatible JSON artifact under experiments/bench/.

    `cells` maps a stable cell key to at least `{"wall_s": float}` (plus any
    informational fields); `parity` maps keys to values the gate checks for
    exact equality; `extra` carries legacy payload fields for older
    consumers and is ignored by the gate.
    """
    payload = dict(extra or {})
    payload.update({
        "benchmark": name,
        "schema": SCHEMA,
        "meta": meta or {},
        "cells": cells,
        "parity": parity or {},
    })
    return save_result(name, payload)
