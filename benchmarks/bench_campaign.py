"""Campaign throughput benchmark: the paper's multi-country study in one go.

    PYTHONPATH=src python benchmarks/bench_campaign.py [--accept 50]

Runs a fresh campaign over the three bundled countries x two (A,R,D)-observing
models and records per-scenario wall clock, acceptance rates and the
compile-reuse ratio (scenarios per compiled shape). The JSON artifact is the
nightly-CI record of the multi-scenario workload's performance trajectory.
"""

import argparse
import shutil
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))
from _harness import emit_artifact, render_table, roofline_fields  # noqa: E402

from repro.core.campaign import CampaignConfig, run_campaign  # noqa: E402


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--accept", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8192)
    ap.add_argument("--days", type=int, default=20)
    ap.add_argument("--models", nargs="+", default=["siard", "seiard"])
    ap.add_argument("--quantile", type=float, default=2e-3)
    args = ap.parse_args(argv)

    out_dir = tempfile.mkdtemp(prefix="bench_campaign_")
    try:
        cfg = CampaignConfig(
            datasets=("italy", "new_zealand", "usa"),
            models=tuple(args.models),
            batch_size=args.batch,
            num_days=args.days,
            target_accepted=args.accept,
            auto_quantile=args.quantile,
            max_runs=2000,
            out_dir=out_dir,
            checkpoint_every=0,  # benchmark the uninterrupted path
        )
        report = run_campaign(cfg)
    finally:
        shutil.rmtree(out_dir, ignore_errors=True)

    rows = []
    for r in report.scenarios:
        sims_per_s = r.simulations / max(r.wall_time_s, 1e-9)
        rows.append([r.name, r.status, str(r.runs), f"{r.acceptance_rate:.2e}",
                     f"{r.wall_time_s:.2f}", f"{sims_per_s:,.0f}"])
    print(render_table(
        ["scenario", "status", "runs", "acc_rate", "wall_s", "sims/s"], rows))

    n_run = sum(1 for r in report.scenarios if r.status == "ok")
    # the campaign/total roofline aggregates the per-scenario analytic
    # totals (each scenario's own model spec) over the campaign wall clock
    from repro.core.tuning import cost_model, roofline_from_totals

    total_flops = total_bytes = 0.0
    for r in report.scenarios:
        if r.simulations:
            cm = cost_model(r.model, args.days)
            total_flops += cm.flops(r.simulations)
            total_bytes += cm.fused_bytes(r.simulations)
    cells = {"campaign/total": {
        "wall_s": report.wall_time_s,
        **(roofline_from_totals(total_flops, total_bytes, report.wall_time_s)
           if total_flops else {}),
    }}
    # statuses are the campaign's structural outcome — a cell flipping from
    # "ok" to "budget_exhausted" (or a scenario disappearing) is a parity
    # drift the gate must catch; wall-clock-derived numbers are NOT parity
    parity = {r.name: r.status for r in report.scenarios}
    for r in report.scenarios:
        cells[f"scenario/{r.name}"] = {
            "wall_s": r.wall_time_s,
            "sims_per_s": r.simulations / max(r.wall_time_s, 1e-9),
            "runs": r.runs,
            "simulations": r.simulations,
            **roofline_fields(r.model, args.days, r.simulations,
                              r.wall_time_s),
        }
    extra = {
        "wall_time_s": report.wall_time_s,
        "compiled_shapes": report.compiled_shapes,
        "scenarios_per_shape": n_run / max(report.compiled_shapes, 1),
        "total_simulations": sum(r.simulations for r in report.scenarios),
        "scenarios": [
            {
                "name": r.name, "status": r.status, "runs": r.runs,
                "simulations": r.simulations, "wall_time_s": r.wall_time_s,
                "acceptance_rate": r.acceptance_rate,
                "tolerance": r.tolerance,
                "posterior_mean": r.posterior_mean,
            }
            for r in report.scenarios
        ],
    }
    path = emit_artifact(
        "campaign",
        cells=cells,
        parity=parity,
        meta={"accept": args.accept, "batch": args.batch, "days": args.days,
              "models": args.models, "quantile": args.quantile},
        extra=extra,
    )
    print(f"\nsaved {path}")
    return extra


if __name__ == "__main__":
    main()
