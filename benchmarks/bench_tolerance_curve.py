"""Paper Fig 6: total processing time grows super-exponentially as the
tolerance decreases (claim C4). Measured via acceptance-rate estimation on a
large prior sample: expected total time = time/run x target / (rate x batch).
The smallest tolerances are extrapolated exactly the way the paper sizes its
5-hour runs."""

from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import render_table, save_result, time_fn
from repro.core.abc import ABCConfig, abc_run_batch, make_simulator
from repro.core.priors import paper_prior
from repro.epi.data import get_dataset

DAYS = 20
BATCH = 16384


def run(quick: bool = True):
    ds = get_dataset("synthetic_small", num_days=DAYS)
    cfg = ABCConfig(batch_size=BATCH, tolerance=np.inf, target_accepted=1,
                    strategy="topk", top_k=1, num_days=DAYS, backend="xla_fused")
    sim = jax.jit(make_simulator(ds, cfg))
    # estimate the distance distribution on ~1M prior simulations
    n_probe = 20 if quick else 60
    dists = []
    for r in range(n_probe):
        th = paper_prior().sample(jax.random.fold_in(jax.random.PRNGKey(7), r), (BATCH,))
        d = np.asarray(sim(th, jax.random.fold_in(jax.random.PRNGKey(8), r)))
        dists.append(d[np.isfinite(d)])
    d = np.concatenate(dists)

    run_fn = jax.jit(abc_run_batch(paper_prior(), make_simulator(ds, cfg), cfg))
    tpr = time_fn(lambda k=jax.random.PRNGKey(1): run_fn(k), iters=3)["p50_s"]

    rows, raw = [], {"time_per_run_s": tpr, "n_sims": len(d)}
    for tol in (2.2e4, 1.8e4, 1.4e4, 1.0e4, 7e3, 5e3):
        rate = float((d <= tol).mean())
        if rate > 0:
            total = tpr * 100 / (rate * BATCH)
            rows.append([f"{tol:.2g}", f"{rate:.2e}", f"{total:.1f}"])
            raw[f"tol_{tol:g}"] = {"accept_rate": rate, "expected_total_s_100": total}
        else:
            rows.append([f"{tol:.2g}", f"<{1.0/len(d):.1e}",
                         f">{tpr * 100 * len(d) / BATCH / 1:.0f}"])
    print("\n== Fig 6 analogue: tolerance -> expected total time (100 samples) ==")
    print(render_table(["tolerance", "accept_rate", "expected_total_s"], rows))
    rates = [v["accept_rate"] for k, v in raw.items() if k.startswith("tol_")]
    if len(rates) >= 3:
        # super-exponential check: successive rate ratios shrink
        ratios = [rates[i + 1] / rates[i] for i in range(len(rates) - 1)]
        print(f"C4: acceptance-rate decay ratios {['%.3f' % r for r in ratios]} "
              f"({'accelerating decay' if ratios[-1] < ratios[0] else 'check'})")
    save_result("fig6_tolerance_curve", raw)
    return raw


if __name__ == "__main__":
    run()
