"""Paper Table 8 / §5: three-country posterior study (claim C2).

The bundled country series are generated from the paper's Table 8 posterior
means (offline stand-in for the JHU feed), so the check is well-posed: our
posterior means should land near the generating parameters. Tolerances are
re-calibrated per dataset (the paper does the same — "the tolerance had to be
adjusted on an individual basis") to keep CPU runtime in minutes.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import render_table, save_result
from repro.core.priors import paper_prior
from repro.core.smc import SMCConfig, run_smc_abc
from repro.epi.data import get_dataset
from repro.epi.model import PARAM_NAMES

DAYS = 25  # paper uses 49; reduced for CPU wall-time, same pipeline


def run(quick: bool = True):
    rows, raw = [], {}
    for country in ("italy", "new_zealand", "usa"):
        ds = get_dataset(country, num_days=DAYS)
        cfg = SMCConfig(
            n_particles=48 if quick else 128,
            batch_size=4096 if quick else 16384,
            n_rounds=3 if quick else 5,
            num_days=DAYS,
        )
        post = run_smc_abc(ds, cfg, key=1)
        mu = post.mean()
        rows.append([country, f"{post.tolerance:.3g}", f"{post.wall_time_s:.1f}",
                     len(post)] + [f"{mu[p]:.3f}" for p in PARAM_NAMES])
        err = {}
        for i, p in enumerate(PARAM_NAMES):
            err[p] = abs(mu[p] - ds.true_theta[i]) / paper_prior().highs[i]
        raw[country] = {"mean": mu, "tolerance": post.tolerance,
                        "runtime_s": post.wall_time_s,
                        "norm_err": err, "true_theta": list(ds.true_theta)}
    print("\n== Table 8 analogue: three-country posteriors (SMC-ABC) ==")
    print(render_table(
        ["country", "tol", "time_s", "N"] + list(PARAM_NAMES), rows))
    mean_err = np.mean([np.mean(list(raw[c]["norm_err"].values())) for c in raw])
    print(f"C2: mean normalized |posterior mean - generating theta| = {mean_err:.3f} "
          f"(prior-mean baseline ~0.25-0.5)")
    save_result("table8_countries", raw)
    return raw


if __name__ == "__main__":
    run()
