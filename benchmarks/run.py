"""Benchmark aggregator: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # quick (CPU-minutes)
    PYTHONPATH=src python -m benchmarks.run --full
    PYTHONPATH=src python -m benchmarks.run --only table1,roofline
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback

BENCHES = {
    "table1": ("benchmarks.bench_runtime", "Table 1: runtime vs tolerance/accepted"),
    "table2_3": ("benchmarks.bench_batch_sweep", "Tables 2-3: batch-size sweep"),
    "table4": ("benchmarks.bench_postproc", "Table 4: host postprocessing"),
    "fig6": ("benchmarks.bench_tolerance_curve", "Fig 6: tolerance curve"),
    "table7": ("benchmarks.bench_scaling", "Table 7: device scaling"),
    "table8": ("benchmarks.bench_countries", "Table 8: three countries"),
    "abc_perf": ("benchmarks.bench_abc_perf", "ABC backend perf + 512-chip dry-run"),
    "roofline": ("benchmarks.roofline", "Roofline aggregation"),
}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default="")
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else set(BENCHES)

    failures = []
    t0 = time.time()
    for key, (module, desc) in BENCHES.items():
        if key not in only:
            continue
        print(f"\n{'='*72}\n[bench:{key}] {desc}\n{'='*72}", flush=True)
        try:
            mod = __import__(module, fromlist=["run"])
            t = time.time()
            mod.run(quick=not args.full)
            print(f"[bench:{key}] done in {time.time()-t:.1f}s", flush=True)
        except Exception:
            failures.append(key)
            traceback.print_exc()
    print(f"\n{'='*72}\nbenchmarks finished in {time.time()-t0:.1f}s; "
          f"failures: {failures or 'none'}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
