"""Benchmark aggregator: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # quick (CPU-minutes)
    PYTHONPATH=src python -m benchmarks.run --full
    PYTHONPATH=src python -m benchmarks.run --only table1,roofline
"""

from __future__ import annotations

import argparse
import shutil
import sys
import time
import traceback
from pathlib import Path

BENCHES = {
    "table1": ("benchmarks.bench_runtime", "Table 1: runtime vs tolerance/accepted"),
    "table2_3": ("benchmarks.bench_batch_sweep", "Tables 2-3: batch-size sweep"),
    "table4": ("benchmarks.bench_postproc", "Table 4: host postprocessing"),
    "fig6": ("benchmarks.bench_tolerance_curve", "Fig 6: tolerance curve"),
    "table7": ("benchmarks.bench_scaling", "Table 7: device scaling"),
    "table8": ("benchmarks.bench_countries", "Table 8: three countries"),
    "abc_perf": ("benchmarks.bench_abc_perf", "ABC backend perf + 512-chip dry-run"),
    "roofline": ("benchmarks.roofline", "Roofline aggregation"),
}


#: the gate-compatible artifacts with committed baselines: (module, argv).
#: `--refresh` reruns exactly these and copies the fresh JSON over
#: experiments/bench/baselines/ in one command (the re-baselining friction
#: cutter named by the ROADMAP; commit the result in a reviewed change).
BASELINED = {
    "wave_loop.json": ("benchmarks.bench_wave_loop", []),
    "campaign.json": ("benchmarks.bench_campaign", []),
    "scaling.json": ("benchmarks.bench_scaling", []),
}


def refresh_baselines() -> int:
    import importlib

    bench_dir = Path(__file__).resolve().parents[1] / "experiments" / "bench"
    baseline_dir = bench_dir / "baselines"
    baseline_dir.mkdir(parents=True, exist_ok=True)
    failures = []
    for name, (module, argv) in BASELINED.items():
        print(f"\n{'='*72}\n[refresh] {module} -> {name}\n{'='*72}",
              flush=True)
        try:
            importlib.import_module(module).main(list(argv))
        except Exception:
            failures.append(name)
            traceback.print_exc()
            continue
        fresh = bench_dir / name
        if not fresh.exists():
            failures.append(name)
            print(f"[refresh] {module} produced no {fresh}")
            continue
        shutil.copyfile(fresh, baseline_dir / name)
        print(f"[refresh] baselined {baseline_dir / name}")
    if failures:
        print(f"[refresh] FAILED for: {failures}")
        return 1
    print(f"\n[refresh] all baselines regenerated under {baseline_dir}; "
          "review + commit them (tests/check_bench_regression.py gates "
          "against this set)")
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default="")
    ap.add_argument("--refresh", action="store_true",
                    help="regenerate every experiments/bench/baselines/*.json "
                         "in one command (runs the baselined benchmarks with "
                         "their default settings, then copies the fresh "
                         "artifacts over the baselines)")
    args = ap.parse_args(argv)
    if args.refresh:
        sys.exit(refresh_baselines())
    only = set(args.only.split(",")) if args.only else set(BENCHES)

    failures = []
    t0 = time.time()
    for key, (module, desc) in BENCHES.items():
        if key not in only:
            continue
        print(f"\n{'='*72}\n[bench:{key}] {desc}\n{'='*72}", flush=True)
        try:
            mod = __import__(module, fromlist=["run"])
            t = time.time()
            mod.run(quick=not args.full)
            print(f"[bench:{key}] done in {time.time()-t:.1f}s", flush=True)
        except Exception:
            failures.append(key)
            traceback.print_exc()
    print(f"\n{'='*72}\nbenchmarks finished in {time.time()-t0:.1f}s; "
          f"failures: {failures or 'none'}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
