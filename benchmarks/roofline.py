"""Aggregates the dry-run cell records into the EXPERIMENTS.md §Roofline
table, plus the analytic roofline of the paper's own ABC kernel."""

from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import render_table, save_result
from repro.ioutils import atomic_write_text
from repro.launch.analysis import HBM_BW, LINK_BW, PEAK_FLOPS

DRYRUN_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def load_cells(mesh="single", tag="baseline"):
    cells = []
    for p in sorted(DRYRUN_DIR.glob(f"*__{mesh}__{tag}.json")):
        cells.append(json.loads(p.read_text()))
    return cells


def advice(cell: dict) -> str:
    """One sentence per cell: what would move the dominant term down."""
    r = cell["roofline"]
    bound = r["bottleneck"]
    arch, shape = cell["arch"], cell["shape"]
    is_moe = "moe" in arch
    over_hbm = cell["memory"]["peak_hbm_bytes"] > 16 * 2**30
    extra = " (over 16GB HBM: use microbatch knob or the multi-pod mesh)" if over_hbm else ""
    if bound == "collective":
        if is_moe:
            return ("EP dispatch traffic: grouped per-shard dispatch + bf16 "
                    "on-wire all-to-all (§Perf cell 1, 2.2-2.4x measured)" + extra)
        if cell["mode"] == "train":
            return ("grad/activation all-reduces: overlap collectives with "
                    "backward compute; int8 error-feedback compression on the "
                    "DP grad reduction (optim/compress.py)" + extra)
        return ("TP activation all-reduces: fuse/overlap with matmuls, keep "
                "the wire in bf16" + extra)
    if bound == "memory":
        if cell["mode"] == "decode":
            return ("at the cache/weight streaming floor — raise batch per "
                    "chip, or quantize KV cache to int8 to halve bytes/token")
        return ("f32 elementwise + remat recompute traffic: flash-attention "
                "Pallas kernel (kernels/flash_attention.py, validated) + bf16 "
                "norm/score discipline" + extra)
    return "compute-bound at the MXU roofline: raise per-chip batch" + extra


def roofline_table(mesh="single", tag="baseline") -> str:
    cells = load_cells(mesh, tag)
    rows = []
    for c in cells:
        r = c["roofline"]
        rows.append([
            c["arch"], c["shape"],
            f"{r['t_compute_s']:.2e}", f"{r['t_memory_s']:.2e}",
            f"{r['t_collective_s']:.2e}", r["bottleneck"][:4],
            f"{r['model_flops']:.2e}", f"{r['useful_flop_ratio']:.2f}",
            f"{r['mfu_bound']*100:.1f}%",
            f"{c['memory']['peak_hbm_bytes']/2**30:.1f}",
        ])
    return render_table(
        ["arch", "shape", "t_comp(s)", "t_mem(s)", "t_coll(s)", "bound",
         "model_flops", "useful", "MFU@roof", "HBM GiB"],
        rows,
    )


def abc_kernel_roofline(
    batch: int = 100_000,
    days: int = 49,
    model: str = "siard",
    summary=None,
    distance: str = "euclidean",
) -> dict:
    """Analytic roofline of the fused Pallas ABC kernel (no matmuls — the
    HLO dot counter sees none), derived from the MODEL SPEC via the generic
    cost model in repro.core.tuning: the per-day op count is traced from the
    spec's own hazards/RNG/summary accumulator and the byte model follows
    its `n_transitions`/`n_state`/`n_observed`. Nothing here is hardwired to
    the paper's SIARD constants; pass any registered model name."""
    from repro.core.tuning import cost_model

    cm = cost_model(model, days, summary=summary, distance=distance)
    flops = cm.flops(batch)
    hbm_bytes_fused = cm.fused_bytes(batch)  # theta in + distance out
    hbm_bytes_naive = cm.naive_bytes(batch)  # noise+obs+state round trips
    return {
        "model": cm.model,
        "batch": batch,
        "days": days,
        "flops_per_sample_day": cm.flops_per_sample_day,
        "fused_bytes_per_sample": cm.fused_bytes_per_sample,
        "naive_bytes_per_sample_day": cm.naive_bytes_per_sample_day,
        "t_compute_s": flops / PEAK_FLOPS,
        "t_memory_fused_s": hbm_bytes_fused / HBM_BW,
        "t_memory_naive_s": hbm_bytes_naive / HBM_BW,
        "t_collective_s": 4 / LINK_BW,  # scalar psum
        "arithmetic_intensity_fused": flops / hbm_bytes_fused,
        "arithmetic_intensity_naive": flops / hbm_bytes_naive,
        "note": "VPU-bound elementwise workload; MXU bf16 peak is not the "
                "binding ceiling — reported for consistency with the brief",
    }


def write_advice_appendix(path=None) -> str:
    path = path or DRYRUN_DIR.parent / "roofline_advice.md"
    lines = ["# Per-cell dominant-term advice (auto-generated)\n"]
    for mesh in ("single", "multi"):
        lines.append(f"\n## {mesh}-pod mesh\n")
        for c in load_cells(mesh):
            r = c["roofline"]
            lines.append(
                f"- **{c['arch']} × {c['shape']}** [{r['bottleneck']}-bound, "
                f"MFU@roof {r['mfu_bound']*100:.1f}%, useful {r['useful_flop_ratio']:.2f}]: "
                f"{advice(c)}"
            )
    text = "\n".join(lines)
    atomic_write_text(path, text)
    return str(path)


def run(quick: bool = True, model: str = "siard", batch: int = 100_000,
        days: int = 49):
    for mesh in ("single", "multi"):
        cells = load_cells(mesh)
        print(f"\n== Roofline ({mesh}-pod), {len(cells)} cells ==")
        if cells:
            print(roofline_table(mesh))
    p = write_advice_appendix()
    print(f"\nper-cell advice appendix -> {p}")
    abc = abc_kernel_roofline(batch=batch, days=days, model=model)
    print(f"\n== ABC kernel analytic roofline (per chip, model {abc['model']}, "
          f"batch {batch} x {days} days) ==")
    for k, v in abc.items():
        print(f"  {k}: {v}")
    save_result("roofline_abc_kernel", abc)
    return abc


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="siard",
                    help="registry name; the cost model derives the op/byte "
                         "counts from the spec, nothing is SIARD-specific")
    ap.add_argument("--batch", type=int, default=100_000)
    ap.add_argument("--days", type=int, default=49)
    a = ap.parse_args()
    run(model=a.model, batch=a.batch, days=a.days)
