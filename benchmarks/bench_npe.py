"""Amortized-inference economics: NPE train-once cost vs ABC per-fit cost.

    PYTHONPATH=src python benchmarks/bench_npe.py [--queries 32]

The question this artifact answers: after how many posterior queries does
training an NPE estimator (repro.core.npe) pay for itself against re-running
an ABC fit per query? Three measured cells plus the derived amortization
curve:

  * `npe_train`  — one `train_npe` of the CI-sized `configs.epi_abc.npe_demo`
    estimator (wall clock + the simulation budget it spends, once);
  * `npe_query`  — per-query cost of `sample_posterior` on the trained
    estimator (median over --queries distinct observed series; ZERO
    simulations per query);
  * `abc_fit`    — one wave-backed `run_abc` fit of the same (model, days,
    acceptance target) — the per-query cost of NOT amortizing.

`amortization.break_even_queries` = train cost / (per-fit cost - per-query
cost): below it ABC is cheaper, above it NPE wins; `speedup_at_n` reports
the wall-clock ratio at the --queries horizon. Emits the gate-compatible
`bench-artifact/v1` envelope, diffed against
`experiments/bench/baselines/npe.json` by tests/check_bench_regression.py
(parity: the deterministic simulation/step counts; wall clocks gated at the
usual threshold).
"""

import argparse
import sys
import time
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parent))
from _harness import emit_artifact  # noqa: E402
from common import render_table  # noqa: E402

from repro.configs.epi_abc import npe_demo  # noqa: E402
from repro.core import npe  # noqa: E402
from repro.core.abc import ABCConfig, run_abc  # noqa: E402
from repro.epi.data import synthetic_dataset  # noqa: E402

#: per-query observed series are fresh synthetic datasets (distinct seeds):
#: the amortized path must be measured on UNSEEN observations, not the
#: training dataset
QUERY_SEED0 = 100


def _query_dataset(workload, seed: int):
    return synthetic_dataset(
        theta=(0.5, 0.2, 1.0), population=1e6,
        num_days=workload.abc.num_days, a0=100.0, seed=seed,
        name=f"npe_query_{seed}", model=workload.abc.model,
    )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", type=int, default=32,
                    help="amortization horizon: distinct posterior queries")
    ap.add_argument("--accept", type=int, default=64,
                    help="acceptance target of the per-query ABC fit cell")
    args = ap.parse_args(argv)

    workload = npe_demo()
    ds = workload.load_dataset()
    npe_cfg = workload.abc.npe

    # -- train once --------------------------------------------------------
    est = npe.train_npe(ds, workload.abc, key=0)
    train = {
        "wall_s": est.train_wall_s,
        "simulations": est.train_sims,
        "sims_per_s": est.train_sims / est.train_wall_s,
        "train_steps": est.train_steps_done,
        "final_nll": float(est.final_loss),
    }

    # -- query many --------------------------------------------------------
    est.sample_posterior(ds.observed, workload.abc.target_accepted)  # warmup
    per_query = []
    for i in range(args.queries):
        q = _query_dataset(workload, QUERY_SEED0 + i)
        t0 = time.perf_counter()
        post = est.sample_posterior(
            q.observed, workload.abc.target_accepted, key=i
        )
        per_query.append(time.perf_counter() - t0)
        assert post.runs == 0  # zero waves per query, by construction
    query = {
        "wall_s": float(np.median(per_query)),
        "wall_s_p90": float(np.quantile(per_query, 0.9)),
        "queries": args.queries,
        "draws_per_query": workload.abc.target_accepted,
        "simulations_per_query": 0,
    }

    # -- the unamortized alternative: one wave-backed fit per query --------
    abc_cfg = ABCConfig(
        batch_size=4096, chunk_size=4096, tolerance=float("inf"),
        strategy="topk", top_k=args.accept, target_accepted=args.accept,
        max_runs=8, num_days=workload.abc.num_days, backend="xla_fused",
        model=workload.abc.model,
    )
    t0 = time.perf_counter()
    abc_post = run_abc(ds, abc_cfg, key=0)
    abc_wall = time.perf_counter() - t0
    abc_fit = {
        "wall_s": abc_wall,
        "simulations": abc_post.simulations,
        "accepted": len(abc_post),
    }

    # -- amortization ------------------------------------------------------
    saving = abc_fit["wall_s"] - query["wall_s"]
    break_even = (
        train["wall_s"] / saving if saving > 0 else float("inf")
    )
    n = args.queries
    npe_total = train["wall_s"] + n * query["wall_s"]
    abc_total = n * abc_fit["wall_s"]
    amortization = {
        "break_even_queries": break_even,
        "horizon_queries": n,
        "npe_total_wall_s_at_n": npe_total,
        "abc_total_wall_s_at_n": abc_total,
        "speedup_at_n": abc_total / npe_total,
    }

    print(render_table(
        ["cell", "wall_s", "simulations"],
        [["npe_train", f"{train['wall_s']:.2f}", train["simulations"]],
         ["npe_query (median)", f"{query['wall_s']:.4f}", 0],
         ["abc_fit", f"{abc_fit['wall_s']:.2f}", abc_fit["simulations"]]],
    ))
    print(f"\nbreak-even at {break_even:.1f} queries; at n={n}: "
          f"npe {npe_total:.2f}s vs abc {abc_total:.2f}s "
          f"({amortization['speedup_at_n']:.1f}x)")

    path = emit_artifact(
        "npe",
        cells={"npe_train": train, "npe_query": query, "abc_fit": abc_fit},
        # deterministic by construction: estimator/fit budgets, never wall
        parity={
            "train_steps": npe_cfg.train_steps,
            "train_batch": npe_cfg.train_batch,
            "train_simulations": est.train_sims,
            "n_features": est.n_features,
            "n_params": est.n_params,
            "abc_simulations": abc_post.simulations,
            "draws_per_query": workload.abc.target_accepted,
        },
        meta={"model": workload.abc.model, "days": workload.abc.num_days,
              "queries": args.queries, "accept": args.accept},
        extra={"amortization": amortization},
    )
    print(f"\nsaved {path}")


if __name__ == "__main__":
    main()
