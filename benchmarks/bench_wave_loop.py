"""Host wave loop vs device-resident wave loop: before/after throughput.

    PYTHONPATH=src python benchmarks/bench_wave_loop.py [--batch 8192] [--waves 16]
    # nightly (backend, summary, distance) sweep:
    PYTHONPATH=src python benchmarks/bench_wave_loop.py \
        --backends xla_fused pallas --summaries identity weekly log_weekly \
        --distances euclidean mae normalized_euclidean

Runs the SAME wave budget (target_accepted unreachable, max_runs fixed)
through both drivers of `run_abc`:

  host   — one jitted wave per call, host harvest after every wave
           (the per-wave host sync the paper's outfeed host code pays)
  device — one jitted lax.while_loop over all waves with donated accept
           buffers; a single host sync at the end

Both see identical sample streams (pinned by tests/test_wave_loop.py), so the
delta is pure loop/dispatch overhead. The grid additionally sweeps the
summary-statistic and distance axes (core.summaries): every cell records
`cost_vs_identity_euclidean`, the device-loop throughput of that
(summary, distance) pair relative to the identity+euclidean cell of the same
(model, backend) — the number that tracks what non-euclidean statistics cost
the fused paths over time (the nightly JSON artifact carries it). The JSON
artifact also embeds the raw simulator throughput from
experiments/bench/model_sweep.json (when present) so regressions against the
`bench_model_sweep` baseline are visible in one place — wave-loop sims/s can
approach but never exceed the raw simulator.
"""

import argparse
import json
import sys
from pathlib import Path


sys.path.insert(0, str(Path(__file__).resolve().parent))
from _harness import (  # noqa: E402
    RESULTS_DIR,
    best_of,
    emit_artifact,
    render_table,
    roofline_fields,
)

from repro.core.abc import ABCConfig, make_simulator, run_abc  # noqa: E402
from repro.epi.data import get_dataset  # noqa: E402
from repro.epi.models import get_model  # noqa: E402

DAYS = 20


def calibrate(ds, model, backend, summary, distance, quantile=0.01):
    """Per-cell epsilon at ~1% acceptance so the accept path carries
    realistic traffic for every (model, summary, distance) scale — the
    production pilot-wave calibration, not a benchmark-local copy."""
    from repro.core.abc import calibrate_tolerance

    cfg = ABCConfig(batch_size=4096, num_days=DAYS, chunk_size=4096,
                    backend=backend, model=model, summary=summary,
                    distance=distance)
    return calibrate_tolerance(ds, cfg, key=42, quantile=quantile,
                               n_pilot=4096)


def make_driver(ds, cfg):
    """Pre-build the compiled runner so timing excludes trace/compile."""
    import jax as _jax

    from repro.core.abc import abc_run_batch, make_wave_runner

    prior = get_model(cfg.model).prior()
    sim = make_simulator(ds, cfg)
    if cfg.wave_loop == "device":
        runner = make_wave_runner(prior, sim, cfg)
        return lambda key: run_abc(ds, cfg, key=key, wave_runner=runner)
    run_fn = _jax.jit(abc_run_batch(prior, sim, cfg))
    return lambda key: run_abc(ds, cfg, key=key, run_fn=run_fn)


def tile_study(args, cells, parity, rows):
    """Hardwired tile=1024 vs the measured tile sweep, end to end.

    Runs the device wave loop of the FIRST requested model on the pallas
    backend at every compatible kernel tile, records one gated cell per
    tile (`tile_study/{model}/pallas/tile{t}`), persists the winner to the
    tuning cache under experiments/tuning/, and reports whether the
    autotuned tile beat the old hardwired 1024 default. The acceptance
    counts are parity-gated EQUAL across tiles: the kernel's global sample
    index makes the RNG tile-invariant, so tiling is pure scheduling.
    """
    from repro.core import tuning

    model = args.models[0]
    waves = min(args.waves, 4)  # the sweep needs relative, not long, runs
    ds = get_dataset("synthetic_small", num_days=DAYS, model=model)
    tol = calibrate(ds, model, "pallas", "identity", "euclidean")
    target = waves * args.batch + 1
    cands = tuning.tile_candidates(args.batch)
    if 1024 not in cands and args.batch % 1024 == 0:
        cands = tuple(sorted(set(cands) | {1024}))
    study = {"model": model, "batch": args.batch, "waves": waves,
             "tiles": {}, "default_tile": 1024}
    for t in cands:
        cfg = ABCConfig(
            batch_size=args.batch, tolerance=tol, target_accepted=target,
            max_runs=waves, chunk_size=args.batch, num_days=DAYS,
            backend="pallas", model=model, wave_loop="device", tile=int(t),
        )
        driver = make_driver(ds, cfg)
        post, dt = best_of(driver, 1, reps=args.reps, warmup=1)
        key = f"tile_study/{model}/pallas/tile{t}"
        cells[key] = {
            "wall_s": dt, "simulations": post.simulations,
            "sims_per_s": post.simulations / dt, "tile": int(t),
            **roofline_fields(model, DAYS, post.simulations, dt),
        }
        # tile invariance is the contract: same accepted count AND same
        # simulation budget at every tile, exact-gated
        parity[key] = {"simulations": post.simulations,
                       "n_accepted": len(post)}
        study["tiles"][str(t)] = {"wall_s": dt,
                                  "sims_per_s": post.simulations / dt}
        rows.append([model, "pallas", f"tile={t}", "euclidean", "device",
                     f"{dt*1e3:.1f}", f"{post.simulations / dt:,.0f}"])
    best = min(study["tiles"], key=lambda k: study["tiles"][k]["wall_s"])
    study["autotuned_tile"] = int(best)
    d1024 = study["tiles"].get("1024")
    study["autotuned_beats_default"] = bool(
        d1024 is not None and study["tiles"][best]["wall_s"] < d1024["wall_s"]
    )
    # persist the end-to-end winner so --autotune runs pick it up
    cfg0 = ABCConfig(batch_size=args.batch, chunk_size=args.batch,
                     num_days=DAYS, backend="pallas", model=model)
    cache = tuning.TuningCache()
    cache.put(tuning.cfg_cache_key(cfg0), {
        "schema": tuning.CACHE_SCHEMA, "backend": "pallas", "model": model,
        "days": DAYS, "batch": args.batch, "summary": "identity",
        "distance": "euclidean", "schedule": "nosched",
        "tile": int(best), "best_batch": None,
        "measurements": {f"tile{t}": v["wall_s"]
                         for t, v in study["tiles"].items()},
    })
    study["cache_path"] = str(cache.path)
    print(f"[tile-study] winner tile={best} "
          f"(beats 1024: {study['autotuned_beats_default']}); "
          f"cached -> {cache.path}")
    return study


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=8192)
    ap.add_argument("--waves", type=int, default=16)
    ap.add_argument("--models", nargs="+", default=["siard", "sir"])
    ap.add_argument("--backends", nargs="+", default=["xla_fused"])
    ap.add_argument("--summaries", nargs="+", default=["identity"],
                    help="summary-statistic sweep axis (core.summaries names)")
    ap.add_argument("--distances", nargs="+", default=["euclidean"],
                    help="distance-kind sweep axis (core.summaries names)")
    ap.add_argument("--reps", type=int, default=3,
                    help="timed repetitions per cell (best-of; warmup "
                         "excluded) — single runs are too noisy to track "
                         "the summary-statistic cost")
    ap.add_argument("--out-name", default="wave_loop",
                    help="artifact basename under experiments/bench/ (the "
                         "nightly job writes the default run and the summary "
                         "sweep to separate JSON files)")
    ap.add_argument("--no-tile-study", action="store_true",
                    help="skip the pallas tile sweep (hardwired 1024 vs "
                         "measured winner) that rides along by default")
    args = ap.parse_args(argv)

    # unreachable target so both drivers burn the full wave budget, but small
    # enough that the accept buffer (target + batch rows) stays device-sized
    target = args.waves * args.batch + 1

    rows, runs = [], []
    cells, parity = {}, {}
    # identity+euclidean device-loop sims/s per (model, backend): the
    # baseline the sweep cells are costed against
    baseline: dict = {}
    grid = [(s, d) for s in args.summaries for d in args.distances]
    # the baseline cell must run FIRST (every other cell is costed against
    # it), wherever — or whether — it appeared in the requested grid
    base_pair = ("identity", "euclidean")
    if base_pair in grid:
        grid.remove(base_pair)
    grid.insert(0, base_pair)
    for model in args.models:
        ds = get_dataset("synthetic_small", num_days=DAYS, model=model)
        for backend in args.backends:
            for summary, distance in grid:
                tol = calibrate(ds, model, backend, summary, distance)
                per_loop = {}
                for loop in ("host", "device"):
                    cfg = ABCConfig(
                        batch_size=args.batch, tolerance=tol,
                        target_accepted=target, max_runs=args.waves,
                        chunk_size=args.batch, num_days=DAYS, backend=backend,
                        model=model, wave_loop=loop,
                        summary=summary, distance=distance,
                    )
                    driver = make_driver(ds, cfg)
                    post, dt = best_of(driver, 1, reps=args.reps, warmup=1)
                    sims_per_s = post.simulations / dt
                    per_loop[loop] = {
                        "wall_s": dt, "simulations": post.simulations,
                        "sims_per_s": sims_per_s,
                        # roofline instrumentation: measured throughput vs the
                        # analytic ceiling of THIS (model, summary, distance)
                        **roofline_fields(model, DAYS, post.simulations, dt,
                                          summary=summary, distance=distance),
                    }
                    if backend == "pallas":
                        from repro.kernels.ops import resolve_tile

                        # surface the kernel tile actually used in the cell
                        per_loop[loop]["tile"] = resolve_tile(
                            args.batch, cfg.tile
                        )
                    key = f"{model}/{backend}/{summary}/{distance}/{loop}"
                    cells[key] = dict(per_loop[loop])
                    # the wave budget is fixed (unreachable target), so the
                    # simulation count is deterministic — a parity metric
                    parity[key] = post.simulations
                    rows.append([model, backend, summary, distance, loop,
                                 f"{dt*1e3:.1f}", f"{sims_per_s:,.0f}"])
                speedup = (per_loop["device"]["sims_per_s"]
                           / per_loop["host"]["sims_per_s"])
                if (summary, distance) == ("identity", "euclidean"):
                    baseline[(model, backend)] = per_loop["device"]["sims_per_s"]
                base = baseline.get((model, backend))
                cost = (per_loop["device"]["sims_per_s"] / base) if base else None
                runs.append({
                    "model": model, "backend": backend, "summary": summary,
                    "distance": distance, **per_loop,
                    "device_over_host_speedup": speedup,
                    # < 1.0 = this statistic costs fused throughput vs the
                    # paper's raw euclidean; the nightly artifact tracks it
                    "cost_vs_identity_euclidean": cost,
                })
                rows.append([model, backend, summary, distance, "speedup", "",
                             f"{speedup:.2f}x"])

    # pallas tile sweep: hardwired 1024 vs measured winner, gated per tile
    study = None
    if not args.no_tile_study:
        study = tile_study(args, cells, parity, rows)

    # legacy payload fields (and the raw-simulator baseline, so one artifact
    # shows the trajectory) ride along outside the gated envelope
    extra = {"batch": args.batch, "waves": args.waves, "reps": args.reps,
             "runs": runs}
    if study is not None:
        extra["tile_study"] = study
    sweep_path = RESULTS_DIR / "model_sweep.json"
    if sweep_path.exists():
        extra["model_sweep_baseline"] = json.loads(sweep_path.read_text())

    print(render_table(
        ["model", "backend", "summary", "distance", "loop", "wall_ms",
         "sims/s"], rows))
    # basename only: the artifact always lands under experiments/bench/
    path = emit_artifact(
        Path(args.out_name).name,
        cells=cells,
        parity=parity,
        meta={"batch": args.batch, "waves": args.waves, "reps": args.reps,
              "models": args.models, "backends": args.backends,
              "summaries": args.summaries, "distances": args.distances},
        extra=extra,
    )
    print(f"\nsaved {path}")
    return extra


if __name__ == "__main__":
    main()
