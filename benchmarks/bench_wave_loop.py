"""Host wave loop vs device-resident wave loop: before/after throughput.

    PYTHONPATH=src python benchmarks/bench_wave_loop.py [--batch 8192] [--waves 16]

Runs the SAME wave budget (target_accepted unreachable, max_runs fixed)
through both drivers of `run_abc`:

  host   — one jitted wave per call, host harvest after every wave
           (the per-wave host sync the paper's outfeed host code pays)
  device — one jitted lax.while_loop over all waves with donated accept
           buffers; a single host sync at the end

Both see identical sample streams (pinned by tests/test_wave_loop.py), so the
delta is pure loop/dispatch overhead. The JSON artifact also embeds the raw
simulator throughput from experiments/bench/model_sweep.json (when present)
so regressions against the `bench_model_sweep` baseline are visible in one
place — wave-loop sims/s can approach but never exceed the raw simulator.
"""

import argparse
import json
import sys
import time
from pathlib import Path

import jax
import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parent))
from common import RESULTS_DIR, render_table, save_result  # noqa: E402

from repro.core.abc import ABCConfig, make_simulator, run_abc  # noqa: E402
from repro.epi.data import get_dataset  # noqa: E402
from repro.epi.models import get_model  # noqa: E402

DAYS = 20


def calibrate(ds, model, backend, quantile=0.01):
    """Per-model epsilon at ~1% acceptance so the accept path carries
    realistic traffic for every model's distance scale."""
    cfg = ABCConfig(batch_size=4096, num_days=DAYS, chunk_size=4096,
                    backend=backend, model=model)
    sim = jax.jit(make_simulator(ds, cfg))
    th = get_model(model).prior().sample(jax.random.PRNGKey(42), (4096,))
    d = np.asarray(sim(th, jax.random.PRNGKey(43)))
    return float(np.quantile(d[np.isfinite(d)], quantile))


def make_driver(ds, cfg):
    """Pre-build the compiled runner so timing excludes trace/compile."""
    import jax as _jax

    from repro.core.abc import abc_run_batch, make_wave_runner

    prior = get_model(cfg.model).prior()
    sim = make_simulator(ds, cfg)
    if cfg.wave_loop == "device":
        runner = make_wave_runner(prior, sim, cfg)
        return lambda key: run_abc(ds, cfg, key=key, wave_runner=runner)
    run_fn = _jax.jit(abc_run_batch(prior, sim, cfg))
    return lambda key: run_abc(ds, cfg, key=key, run_fn=run_fn)


def run_once(driver, key=0):
    t0 = time.perf_counter()
    post = driver(key)
    dt = time.perf_counter() - t0
    return post, dt


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=8192)
    ap.add_argument("--waves", type=int, default=16)
    ap.add_argument("--models", nargs="+", default=["siard", "sir"])
    ap.add_argument("--backends", nargs="+", default=["xla_fused"])
    args = ap.parse_args(argv)

    # unreachable target so both drivers burn the full wave budget, but small
    # enough that the accept buffer (target + batch rows) stays device-sized
    target = args.waves * args.batch + 1

    rows, payload = [], {"batch": args.batch, "waves": args.waves, "runs": []}
    for model in args.models:
        ds = get_dataset("synthetic_small", num_days=DAYS, model=model)
        for backend in args.backends:
            tol = calibrate(ds, model, backend)
            per_loop = {}
            for loop in ("host", "device"):
                cfg = ABCConfig(
                    batch_size=args.batch, tolerance=tol,
                    target_accepted=target, max_runs=args.waves,
                    chunk_size=args.batch, num_days=DAYS, backend=backend,
                    model=model, wave_loop=loop,
                )
                driver = make_driver(ds, cfg)
                run_once(driver, key=0)  # warmup: compile + first wave set
                post, dt = run_once(driver, key=1)
                sims_per_s = post.simulations / dt
                per_loop[loop] = {
                    "wall_s": dt, "simulations": post.simulations,
                    "sims_per_s": sims_per_s,
                }
                rows.append([model, backend, loop, f"{dt*1e3:.1f}",
                             f"{sims_per_s:,.0f}"])
            speedup = (per_loop["device"]["sims_per_s"]
                       / per_loop["host"]["sims_per_s"])
            payload["runs"].append({
                "model": model, "backend": backend, **per_loop,
                "device_over_host_speedup": speedup,
            })
            rows.append([model, backend, "speedup", "",
                         f"{speedup:.2f}x"])

    # embed the raw-simulator baseline so one artifact shows the trajectory
    sweep_path = RESULTS_DIR / "model_sweep.json"
    if sweep_path.exists():
        payload["model_sweep_baseline"] = json.loads(sweep_path.read_text())

    print(render_table(["model", "backend", "loop", "wall_ms", "sims/s"], rows))
    path = save_result("wave_loop", payload)
    print(f"\nsaved {path}")
    return payload


if __name__ == "__main__":
    main()
