"""§Perf for the paper's own workload: paper-faithful baseline vs fused vs
Pallas-kernel ABC, plus the 512-chip dry-run of the sharded ABC step.

Measured on CPU (wall time, real): "xla" (paper-faithful full [B,3,T]
trajectory + separate distance) vs "xla_fused" (running distance, no
trajectory). The Pallas path is validated in interpret mode (correctness) and
projected with the mandated v5e constants via its analytic traffic model —
interpret-mode wall time is meaningless and never reported as performance.
"""

from __future__ import annotations

import os
import subprocess
import sys

import jax

from benchmarks.common import render_table, save_result, time_fn
from benchmarks.roofline import abc_kernel_roofline
from repro.core.abc import ABCConfig, abc_run_batch, make_simulator
from repro.core.priors import paper_prior
from repro.epi.data import get_dataset
from repro.launch.analysis import analyze_hlo

DAYS = 49  # full paper horizon for this one
BATCH = 16384

_DRYRUN_CODE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import jax, json
from repro.core.abc import ABCConfig, make_simulator
from repro.core.distributed import make_shardmap_runner
from repro.core.priors import paper_prior
from repro.epi.data import get_dataset
from repro.launch.analysis import analyze_hlo
from repro.launch.mesh import make_production_mesh

for multi in (False, True):
    mesh = make_production_mesh(multi_pod=multi)
    n = mesh.size
    ds = get_dataset("italy", num_days=49)
    cfg = ABCConfig(batch_size=100_000 * n, tolerance=5e4, target_accepted=10**9,
                    chunk_size=10_000, num_days=49, backend="xla_fused",
                    max_runs=1)
    runner = make_shardmap_runner(mesh, paper_prior(), make_simulator(ds, cfg), cfg)
    lowered = runner.lower(jax.random.PRNGKey(0))
    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    costs = analyze_hlo(compiled.as_text())
    print("DRYRUN", json.dumps({
        "mesh": "2x16x16" if multi else "16x16",
        "devices": n,
        "global_batch": cfg.batch_size,
        "peak_hbm_bytes": mem.argument_size_in_bytes + mem.output_size_in_bytes
                          + mem.temp_size_in_bytes - mem.alias_size_in_bytes,
        "collective_wire_bytes": costs.total_wire,
        "collective_detail": {k: float(v) for k, v in costs.collective_wire.items()},
        "bytes_per_device": costs.bytes_accessed,
    }))
"""


def run(quick: bool = True):
    ds = get_dataset("italy", num_days=DAYS)
    rows, raw = [], {}
    # --- measured: paper-faithful vs fused (real CPU wall time) ---
    for backend in ("xla", "xla_fused"):
        cfg = ABCConfig(batch_size=BATCH, tolerance=5e4, target_accepted=10**9,
                        chunk_size=2048, num_days=DAYS, backend=backend, max_runs=1)
        run_fn = jax.jit(abc_run_batch(paper_prior(), make_simulator(ds, cfg), cfg))
        costs = analyze_hlo(run_fn.lower(jax.random.PRNGKey(0)).compile().as_text())
        t = time_fn(lambda k=jax.random.PRNGKey(1): run_fn(k), iters=5)
        rows.append([backend, f"{t['p50_s']*1e3:.1f}",
                     f"{costs.bytes_accessed/1e6:.0f}",
                     f"{costs.bytes_accessed/BATCH:.0f}"])
        raw[backend] = {"ms_per_run": t["p50_s"] * 1e3,
                        "bytes_accessed": costs.bytes_accessed,
                        "bytes_per_sample": costs.bytes_accessed / BATCH}
    # --- pallas kernel: correctness already covered by tests; analytic roofline
    roof = abc_kernel_roofline(batch=100_000, days=DAYS)
    raw["pallas_analytic"] = roof

    # --- kernel tile sweep: VMEM working set per grid cell (structural knob;
    # correctness across tiles is asserted in tests/test_kernel_abc_sim.py).
    # Working set = theta(8xTB) + state(7xTB incl. acc) + ~10 live temps, f32.
    tile_rows = []
    for tile in (256, 512, 1024, 2048, 4096, 8192):
        vmem_kb = (8 + 7 + 10) * tile * 4 / 1024
        cells_in_vmem = int(16 * 1024 // max(vmem_kb, 1))
        tile_rows.append([tile, f"{vmem_kb:.0f}", cells_in_vmem])
        raw[f"tile_{tile}"] = {"vmem_kb": vmem_kb}
    print("\n== Pallas kernel tile sweep (VMEM per grid cell, 16MB budget) ==")
    print(render_table(["tile (samples)", "VMEM KB", "concurrent cells"], tile_rows))
    print("choice: tile=1024 (default) keeps ~100 KB/cell — deep multi-cell "
          "pipelining headroom while staying lane-aligned (8 x 128)")
    print("\n== ABC backends (batch 16384 x 49 days, measured on CPU) ==")
    print(render_table(["backend", "ms/run", "MB accessed", "B/sample"], rows))
    speed = raw["xla"]["ms_per_run"] / raw["xla_fused"]["ms_per_run"]
    mem_cut = raw["xla"]["bytes_per_sample"] / raw["xla_fused"]["bytes_per_sample"]
    print(f"fused vs paper-faithful: {speed:.2f}x wall, {mem_cut:.2f}x less traffic")
    print(f"pallas kernel (projected, v5e): AI fused={roof['arithmetic_intensity_fused']:.0f} "
          f"vs naive={roof['arithmetic_intensity_naive']:.1f} flops/B; "
          f"t_mem fused={roof['t_memory_fused_s']*1e6:.1f}us vs naive={roof['t_memory_naive_s']*1e6:.0f}us per run")

    # --- 512-chip dry run of the sharded ABC step ---
    if not quick or os.environ.get("REPRO_ABC_DRYRUN", "1") == "1":
        env = dict(os.environ)
        env["PYTHONPATH"] = "src:."
        out = subprocess.run(
            [sys.executable, "-c", _DRYRUN_CODE], env=env, capture_output=True,
            text=True, timeout=900,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        )
        assert out.returncode == 0, out.stderr[-2000:]
        import json as _json

        for line in out.stdout.splitlines():
            if line.startswith("DRYRUN"):
                rec = _json.loads(line[len("DRYRUN "):])
                raw[f"dryrun_{rec['mesh']}"] = rec
                print(f"ABC dry-run {rec['mesh']}: {rec['devices']} chips, "
                      f"global batch {rec['global_batch']:,}, "
                      f"hbm/dev {rec['peak_hbm_bytes']/2**20:.0f} MiB, "
                      f"collective wire {rec['collective_wire_bytes']/1e3:.1f} KB "
                      f"({rec['collective_detail']})")
    save_result("abc_perf", raw)
    return raw


if __name__ == "__main__":
    run()
