"""Throughput-vs-device-count scaling benchmark (the paper's 16-IPU figure).

    PYTHONPATH=src python benchmarks/bench_scaling.py \
        [--devices 1 2 4 8] [--batch-per-device 2048] [--waves 4] \
        [--models siard] [--backends xla_fused]

Runs `repro.core.scaling.run_scaling_study`: the sharded device-resident
wave loop (`distributed.make_wave_runner`, collective stop via psum,
per-shard accept buffers gathered at host re-entry) over a fixed wave
budget at every device count, under weak scaling (global batch = n *
batch_per_device — the paper's "2x100k means 100k per IPU"). Every
(model, backend, batch, n) cell records `parallel_efficiency` and
`scaling_overhead_pct`, the reproduction's analogue of the paper's <= 8%
overhead claim at 16 IPUs.

On a CPU host with fewer visible devices than the sweep needs, the script
re-execs itself once with `--xla_force_host_platform_device_count` set, so
the nightly job measures the structural overhead curve on simulated host
devices (the wall-clock cannot speed up on one physical core; efficiency
there tracks dispatch + collective overhead, which is exactly what the
regression gate pins). The JSON artifact is gate-compatible
(bench-artifact/v1): per-cell wall clocks gated at +25%, simulation counts
gated as parity.
"""

from __future__ import annotations

import argparse
import os
import subprocess
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))
from _harness import emit_artifact, roofline_fields  # noqa: E402

_CHILD_ENV = "_BENCH_SCALING_CHILD"


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", nargs="+", type=int, default=[1, 2, 4, 8],
                    help="device counts of the curve (prefix subsets of the "
                         "visible device pool)")
    ap.add_argument("--batch-per-device", type=int, default=2048,
                    help="per-DEVICE batch (weak scaling: the n-device cell "
                         "simulates n x this per wave)")
    ap.add_argument("--waves", type=int, default=4,
                    help="fixed wave budget per cell (acceptance target is "
                         "unreachable, so every cell burns exactly this)")
    ap.add_argument("--models", nargs="+", default=["siard"])
    ap.add_argument("--backends", nargs="+", default=["xla_fused"])
    ap.add_argument("--days", type=int, default=20)
    ap.add_argument("--dataset", default="synthetic_small")
    ap.add_argument("--reps", type=int, default=3,
                    help="timed repetitions per cell (best-of; warmup "
                         "excluded)")
    ap.add_argument("--out-name", default="scaling",
                    help="artifact basename under experiments/bench/")
    return ap.parse_args(argv)


def _ensure_devices(need: int, argv) -> int | None:
    """Re-exec once with forced host devices when the pool is too small.

    Returns the child's exit code, or None when this process already has
    enough devices (real accelerators, or a caller-set XLA_FLAGS).
    """
    import jax

    if len(jax.devices()) >= need:
        return None
    if jax.default_backend() != "cpu" or os.environ.get(_CHILD_ENV):
        raise SystemExit(
            f"need {need} devices but only {len(jax.devices())} are visible "
            f"on backend {jax.default_backend()!r}"
        )
    env = dict(os.environ)
    env[_CHILD_ENV] = "1"
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={need}"
    ).strip()
    print(f"[bench_scaling] re-exec with {need} simulated host devices")
    return subprocess.run(
        [sys.executable, os.path.abspath(__file__), *argv], env=env
    ).returncode


def main(argv=None):
    argv = list(sys.argv[1:] if argv is None else argv)
    args = parse_args(argv)
    child_rc = _ensure_devices(max(args.devices), argv)
    if child_rc is not None:
        if child_rc:
            raise SystemExit(child_rc)
        return None  # the child produced the artifact

    from repro.core.scaling import (
        ScalingConfig,
        format_report,
        run_scaling_study,
    )

    scfg = ScalingConfig(
        device_counts=tuple(args.devices),
        models=tuple(args.models),
        backends=tuple(args.backends),
        batch_per_device=args.batch_per_device,
        waves=args.waves,
        num_days=args.days,
        dataset=args.dataset,
        reps=args.reps,
    )
    report = run_scaling_study(scfg, verbose=True)
    print()
    print(format_report(report))

    cells, parity = {}, {}
    for key, cell in report["cells"].items():
        cells[key] = {
            "wall_s": cell["wall_s"],
            "sims_per_s": cell["sims_per_s"],
            "parallel_efficiency": cell["parallel_efficiency"],
            "scaling_overhead_pct": cell["scaling_overhead_pct"],
            "devices": cell["devices"],
            "global_batch": cell["global_batch"],
            **roofline_fields(cell["model"], args.days,
                              cell["simulations"], cell["wall_s"]),
        }
        # the wave budget is fixed, so per-cell simulation counts (and the
        # device counts themselves) are deterministic parity metrics
        parity[key] = {
            "simulations": cell["simulations"],
            "devices": cell["devices"],
            "waves": cell["waves"],
        }
    path = emit_artifact(
        Path(args.out_name).name,
        cells=cells,
        parity=parity,
        meta={k: v for k, v in report["config"].items()},
        extra={"report": report},
    )
    print(f"\nsaved {path}")
    return report


def run(quick: bool = True):
    """`benchmarks.run` aggregator entry (the paper's Table 7 slot)."""
    argv = (
        ["--devices", "1", "2", "4", "--batch-per-device", "512",
         "--waves", "2", "--reps", "1", "--days", "15",
         "--out-name", "scaling_quick"]
        if quick
        else ["--devices", "1", "2", "4", "8"]
    )
    return main(argv)


if __name__ == "__main__":
    main()
