"""Paper Table 7: multi-device scaling + chunk-size trade-off (claim C5).

Each row launches a fresh process with a forced host-device count and runs
the shard_map ABC replica. On ONE physical core the wall-clock cannot speed
up; the paper's scaling claim is therefore checked structurally: per-device
work shrinks 1/N while the accept statistics stay constant, and the only
cross-device collective is the scalar psum (counted from the compiled HLO).
"""

from __future__ import annotations

import os
import subprocess
import sys

from benchmarks.common import render_table, save_result

_CODE = r"""
import time, jax, numpy as np
from repro.core.abc import ABCConfig, make_simulator
from repro.core.distributed import make_shardmap_runner
from repro.core.priors import paper_prior
from repro.epi.data import get_dataset
from repro.launch.analysis import analyze_hlo

n = {n}
mesh = jax.make_mesh((n,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
ds = get_dataset("synthetic_small", num_days=15)
cfg = ABCConfig(batch_size=n * 4096, tolerance=1.6e4, target_accepted=10**9,
                chunk_size={chunk}, num_days=15, backend="xla_fused", max_runs=1)
runner = make_shardmap_runner(mesh, paper_prior(), make_simulator(ds, cfg), cfg)
key = jax.random.PRNGKey(3)
lowered = runner.lower(key)
costs = analyze_hlo(lowered.compile().as_text())
out = runner(key); jax.block_until_ready(out)
t0 = time.time()
for r in range(3):
    out = runner(jax.random.fold_in(key, r)); jax.block_until_ready(out)
dt = (time.time() - t0) / 3
total = int(out.accept_count)
coll = {{k: int(v) for k, v in costs.collective_wire.items()}}
print("RESULT", dt, total, cfg.batch_size, coll)
"""


def run(quick: bool = True):
    rows, raw = [], {}
    cases = [(1, 1024), (2, 1024), (4, 1024), (4, 4096)] if quick else [
        (1, 1024), (2, 1024), (4, 1024), (8, 1024), (8, 8192)]
    for n, chunk in cases:
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
        env["PYTHONPATH"] = "src:."
        out = subprocess.run(
            [sys.executable, "-c", _CODE.format(n=n, chunk=chunk)],
            env=env, capture_output=True, text=True, timeout=900,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        )
        assert out.returncode == 0, out.stderr[-2000:]
        line = [l for l in out.stdout.splitlines() if l.startswith("RESULT")][0]
        parts = line.split(None, 4)
        dt, total, gbatch = float(parts[1]), int(parts[2]), int(parts[3])
        coll = eval(parts[4])  # dict literal from our own subprocess
        rate = total / gbatch
        rows.append([n, chunk, f"{dt*1e3:.0f}", f"{rate:.2e}",
                     f"{sum(coll.values())/1e3:.1f}"])
        raw[f"n{n}_chunk{chunk}"] = {
            "time_per_run_s": dt, "accept_rate": rate,
            "collective_wire_bytes": coll,
        }
    print("\n== Table 7 analogue: device scaling & chunk size ==")
    print(render_table(
        ["devices", "chunk", "ms/run(1 core!)", "accept_rate", "coll_KB/run"], rows))
    r1 = raw["n1_chunk1024"]["accept_rate"]
    r4 = raw["n4_chunk1024"]["accept_rate"]
    print(f"C5: accept-rate invariant across device counts: {r1:.2e} vs {r4:.2e}; "
          f"cross-device traffic stays KB-scale (scalar psum + tiny gathers)")
    save_result("table7_scaling", raw)
    return raw


if __name__ == "__main__":
    run()
