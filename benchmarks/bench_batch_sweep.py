"""Paper Tables 2-3 / Fig 3: batch-size sweep — time/run, normalized
time-per-100k-samples, and per-device memory from the compiled artifact.

Paper claim C7: there is a batch-size optimum (normalized throughput curve
flattens/turns). On CPU the curve's turning point sits at smaller batches
than on the IPU, but the shape is the same phenomenon (fixed per-run
overhead amortized vs working set outgrowing near cache).
"""

from __future__ import annotations

import jax

from benchmarks.common import render_table, save_result, time_fn
from repro.core.abc import ABCConfig, abc_run_batch, make_simulator
from repro.core.priors import paper_prior
from repro.epi.data import get_dataset

DAYS = 20


def run(quick: bool = True):
    ds = get_dataset("synthetic_small", num_days=DAYS)
    batches = [1024, 4096, 16384] if quick else [1024, 4096, 16384, 65536, 131072]
    rows, raw = [], {}
    for batch in batches:
        cfg = ABCConfig(
            batch_size=batch, tolerance=1.6e4, target_accepted=10**9,
            chunk_size=min(1024, batch), num_days=DAYS, backend="xla_fused",
            max_runs=1,
        )
        sim = make_simulator(ds, cfg)
        run_fn = jax.jit(abc_run_batch(paper_prior(), sim, cfg))
        lowered = run_fn.lower(jax.random.PRNGKey(0))
        mem = lowered.compile().memory_analysis()
        peak = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                + mem.temp_size_in_bytes - mem.alias_size_in_bytes)
        t = time_fn(lambda k=jax.random.PRNGKey(1): run_fn(k), iters=3)
        per_100k = t["p50_s"] * 1e3 * (1e5 / batch)
        rows.append([batch, f"{t['p50_s']*1e3:.1f}", f"{per_100k:.1f}",
                     f"{peak/2**20:.1f}"])
        raw[batch] = {"ms_per_run": t["p50_s"] * 1e3,
                      "ms_per_100k": per_100k, "peak_mem_mb": peak / 2**20}
    print("\n== Tables 2-3 analogue: batch-size sweep ==")
    print(render_table(["batch", "ms/run", "ms/100k samples", "peak MB"], rows))
    norm = [raw[b]["ms_per_100k"] for b in batches]
    print(f"C7: normalized cost first->last = {norm[0]:.1f} -> {norm[-1]:.1f} ms/100k "
          f"({'amortization visible' if norm[-1] < norm[0] else 'flat'})")
    save_result("table2_3_batch_sweep", raw)
    return raw


if __name__ == "__main__":
    run()
