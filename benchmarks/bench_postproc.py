"""Paper Table 4: host postprocessing time, outfeed vs top-k strategies.

The paper observed postproc is a small fraction of total runtime, grows
~linearly with accepted samples, and is larger for the chunked-outfeed
strategy (more data crosses to host). Same checks here (claim C6)."""

from __future__ import annotations


from benchmarks.common import render_table, save_result
from repro.core.abc import ABCConfig, run_abc
from repro.epi.data import get_dataset

DAYS = 20
BATCH = 8192


def run(quick: bool = True):
    ds = get_dataset("synthetic_small", num_days=DAYS)
    rows, raw = [], {}
    cases = [
        ("outfeed", 1.6e4, 50), ("outfeed", 1.6e4, 200), ("outfeed", 2.1e4, 50),
        ("topk", 1.6e4, 50), ("topk", 1.6e4, 200),
    ]
    for strategy, tol, target in cases:
        cfg = ABCConfig(
            batch_size=BATCH, tolerance=tol, target_accepted=target,
            chunk_size=1024, strategy=strategy, top_k=64, num_days=DAYS,
            backend="xla_fused", max_runs=4000,
        )
        post = run_abc(ds, cfg, key=0)
        pp = getattr(post, "postproc_time_s", 0.0)
        frac = pp / max(post.wall_time_s, 1e-9)
        rows.append([strategy, f"{tol:.2g}", target, len(post),
                     f"{pp*1e3:.1f}", f"{frac:.1%}"])
        raw[f"{strategy}_{tol:g}_{target}"] = {
            "postproc_ms": pp * 1e3, "fraction": frac, "accepted": len(post),
        }
    print("\n== Table 4 analogue: host postprocessing ==")
    print(render_table(
        ["strategy", "tol", "target", "accepted", "postproc_ms", "% of total"], rows))
    of = [raw[k]["fraction"] for k in raw if k.startswith("outfeed")]
    print(f"C6: postproc stays minor (max {max(of):.1%} of wall time for outfeed)")
    save_result("table4_postproc", raw)
    return raw


if __name__ == "__main__":
    run()
