"""Cross-model simulator throughput: every registry model x every backend.

    PYTHONPATH=src python benchmarks/bench_model_sweep.py [--batch 16384]

Times the batched theta -> distance simulator (one ABC run's inner loop) for
each registered compartmental model on the xla / xla_fused / pallas
backends, reporting simulations per second and the per-model state/param
dimensions that size the kernel's VMEM tiles.
"""

import argparse
import sys
from pathlib import Path

import jax

sys.path.insert(0, str(Path(__file__).resolve().parent))
from common import render_table, save_result, time_fn  # noqa: E402

from repro.core.abc import ABCConfig, make_simulator  # noqa: E402
from repro.epi.data import get_dataset  # noqa: E402
from repro.epi.models import get_model, list_models  # noqa: E402

DAYS = 20


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=16384)
    ap.add_argument("--backends", nargs="+",
                    default=["xla", "xla_fused", "pallas"])
    args = ap.parse_args(argv)

    rows, payload = [], []
    for name in list_models():
        spec = get_model(name)
        ds = get_dataset("synthetic_small", num_days=DAYS, model=name)
        theta = spec.prior().sample(jax.random.PRNGKey(0), (args.batch,))
        key = jax.random.PRNGKey(1)
        for backend in args.backends:
            cfg = ABCConfig(batch_size=args.batch, num_days=DAYS,
                            chunk_size=args.batch, backend=backend, model=name)
            sim = jax.jit(make_simulator(ds, cfg))
            t = time_fn(sim, theta, key, warmup=1, iters=3)
            sps = args.batch / t["min_s"]
            rows.append([name, spec.n_state, spec.n_params, backend,
                         f"{t['min_s']*1e3:.1f}", f"{sps:,.0f}"])
            payload.append({"model": name, "backend": backend,
                            "batch": args.batch, "days": DAYS, **t,
                            "sims_per_s": sps})
    print(render_table(
        ["model", "n_state", "n_params", "backend", "min_ms", "sims/s"], rows))
    path = save_result("model_sweep", payload)
    print(f"\nsaved {path}")


if __name__ == "__main__":
    main()
