"""Cross-model simulator throughput: every registry model x every backend,
plus the spatial-metapopulation region-scaling cells.

    PYTHONPATH=src python benchmarks/bench_model_sweep.py [--batch 16384]

Times the batched theta -> distance simulator (one ABC run's inner loop) for
each registered compartmental model on the xla / xla_fused / pallas
backends, reporting simulations per second and the per-model state/param
dimensions that size the kernel's VMEM tiles. The metapop cells regionalize
`metapop_seir` to R in --metapop-regions (ring mobility) on xla_fused —
the backend that covers every R (the pallas kernel's const-lane budget caps
it at R<=10) — tracking how throughput decays as the state width grows
R-fold.

Emits the gate-compatible `bench-artifact/v1` envelope: every cell carries
`wall_s` + roofline fields (repro.core.tuning cost model), diffed against
`experiments/bench/baselines/model_sweep.json` by
tests/check_bench_regression.py.
"""

import argparse
import sys
from pathlib import Path

import jax

sys.path.insert(0, str(Path(__file__).resolve().parent))
from _harness import emit_artifact, roofline_fields  # noqa: E402
from common import render_table, time_fn  # noqa: E402

from repro.core.abc import ABCConfig, make_simulator  # noqa: E402
from repro.epi.data import get_dataset  # noqa: E402
from repro.epi.models import get_model, list_models  # noqa: E402
from repro.epi.spec import regionalize  # noqa: E402

DAYS = 20


def _bench_cell(spec, ds, backend: str, batch: int):
    """Time one (spec, backend) simulator cell; returns the cell dict."""
    theta = spec.prior().sample(jax.random.PRNGKey(0), (batch,))
    key = jax.random.PRNGKey(1)
    cfg = ABCConfig(batch_size=batch, num_days=DAYS, chunk_size=batch,
                    backend=backend, model=spec)
    sim = jax.jit(make_simulator(ds, cfg))
    t = time_fn(sim, theta, key, warmup=1, iters=3)
    sps = batch / t["min_s"]
    return {
        "wall_s": t["min_s"],
        "sims_per_s": sps,
        "batch": batch,
        "days": DAYS,
        **roofline_fields(spec, DAYS, batch, t["min_s"]),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=16384)
    ap.add_argument("--backends", nargs="+",
                    default=["xla", "xla_fused", "pallas"])
    ap.add_argument("--metapop-regions", nargs="+", type=int,
                    default=[1, 10, 100],
                    help="region counts of the metapop_seir scaling cells "
                         "(xla_fused; empty list skips them)")
    ap.add_argument("--metapop-batch", type=int, default=None,
                    help="batch for the metapop cells (default: --batch; "
                         "R=100 widens state 100x, so large batches are "
                         "slow on CPU)")
    args = ap.parse_args(argv)

    rows, cells = [], {}
    for name in list_models():
        spec = get_model(name)
        ds = get_dataset("synthetic_small", num_days=DAYS, model=name)
        for backend in args.backends:
            cell = _bench_cell(spec, ds, backend, args.batch)
            cells[f"{name}/{backend}"] = cell
            rows.append([name, spec.total_state, spec.n_params, backend,
                         f"{cell['wall_s']*1e3:.1f}",
                         f"{cell['sims_per_s']:,.0f}"])

    # region-scaling cells: metapop_seir regionalized to each R, ring
    # mobility; xla_fused covers every R (pallas lane budget caps R at 10)
    mp_batch = args.metapop_batch or args.batch
    for n_regions in args.metapop_regions:
        spec = regionalize(get_model("metapop_seir"), n_regions, "ring:0.1")
        ds = get_dataset("synthetic_small", num_days=DAYS, model=spec)
        cell = _bench_cell(spec, ds, "xla_fused", mp_batch)
        cells[f"metapop_seir_r{n_regions}/xla_fused"] = cell
        rows.append([f"metapop_seir_r{n_regions}", spec.total_state,
                     spec.n_params, "xla_fused",
                     f"{cell['wall_s']*1e3:.1f}",
                     f"{cell['sims_per_s']:,.0f}"])

    print(render_table(
        ["model", "total_state", "n_params", "backend", "min_ms", "sims/s"],
        rows))
    # parity: the swept registry and region axis — deterministic by
    # construction, so silent benchmark narrowing trips the gate
    parity = {
        "registry_models": sorted(list_models()),
        "metapop_regions": sorted(args.metapop_regions),
    }
    path = emit_artifact(
        "model_sweep",
        cells=cells,
        parity=parity,
        meta={"batch": args.batch, "metapop_batch": mp_batch, "days": DAYS,
              "backends": args.backends},
    )
    print(f"\nsaved {path}")


if __name__ == "__main__":
    main()
