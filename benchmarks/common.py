"""Shared benchmark utilities: timing, result persistence, table rendering."""

from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Callable, Dict, List

import jax

from repro.ioutils import atomic_write_text

RESULTS_DIR = Path(__file__).resolve().parents[1] / "experiments" / "bench"


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 5) -> Dict[str, float]:
    """Wall-time a jitted callable (block_until_ready)."""
    for _ in range(warmup):
        jax.tree.map(
            lambda x: x.block_until_ready() if hasattr(x, "block_until_ready") else x,
            fn(*args),
        )
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.tree.map(
            lambda x: x.block_until_ready() if hasattr(x, "block_until_ready") else x,
            out,
        )
        times.append(time.perf_counter() - t0)
    times.sort()
    return {
        "mean_s": sum(times) / len(times),
        "min_s": times[0],
        "p50_s": times[len(times) // 2],
        "iters": iters,
    }


def save_result(name: str, payload) -> Path:
    path = RESULTS_DIR / f"{name}.json"
    return atomic_write_text(path, json.dumps(payload, indent=1, default=float))


def render_table(headers: List[str], rows: List[List]) -> str:
    widths = [
        max(len(str(h)), max((len(str(r[i])) for r in rows), default=0))
        for i, h in enumerate(headers)
    ]
    def fmt(row):
        return " | ".join(str(c).rjust(w) for c, w in zip(row, widths))
    sep = "-+-".join("-" * w for w in widths)
    return "\n".join([fmt(headers), sep] + [fmt(r) for r in rows])
