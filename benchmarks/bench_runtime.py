"""Paper Table 1: runtime vs tolerance and accepted-sample count.

The paper's hardware axis (CPU / V100 / 2xIPU) becomes a backend axis here
(paper-faithful full-trajectory "xla" vs fused "xla_fused" vs the Pallas
kernel path validated in interpret mode — interpret timing is NOT meaningful
and is excluded from timing rows). Validated claims:
  C3 — time/run is independent of tolerance;
  (linear scaling in accepted samples comes out of the run counts).
"""

from __future__ import annotations


import jax

from benchmarks.common import render_table, save_result, time_fn
from repro.core.abc import ABCConfig, abc_run_batch, make_simulator, run_abc
from repro.core.priors import paper_prior
from repro.epi.data import get_dataset

DAYS = 20
BATCH = 8192


def run(quick: bool = True):
    ds = get_dataset("synthetic_small", num_days=DAYS)
    rows = []
    raw = {}
    tolerances = [2.1e4, 1.6e4] if quick else [2.1e4, 1.6e4, 1.2e4]
    accepted_targets = [50, 200] if quick else [100, 1000]
    for backend in ("xla", "xla_fused"):
        for tol in tolerances:
            for target in accepted_targets:
                cfg = ABCConfig(
                    batch_size=BATCH, tolerance=tol, target_accepted=target,
                    chunk_size=1024, num_days=DAYS, backend=backend,
                    max_runs=4000,
                )
                sim = make_simulator(ds, cfg)
                run_fn = jax.jit(abc_run_batch(paper_prior(), sim, cfg))
                # time-per-run micro-measure (paper's reliable metric)
                t = time_fn(lambda k=jax.random.PRNGKey(1): run_fn(k), iters=5)
                post = run_abc(ds, cfg, key=0, run_fn=run_fn)
                rows.append([
                    backend, f"{tol:.2g}", target, len(post), post.runs,
                    f"{post.wall_time_s:.2f}", f"{t['p50_s'] * 1e3:.1f}",
                    f"{post.acceptance_rate:.2e}",
                ])
                raw[f"{backend}_tol{tol:g}_n{target}"] = {
                    "time_per_run_ms": t["p50_s"] * 1e3,
                    "total_s": post.wall_time_s,
                    "runs": post.runs,
                    "accepted": len(post),
                }
    table = render_table(
        ["backend", "tol", "target", "accepted", "runs", "total_s",
         "ms/run", "accept_rate"],
        rows,
    )
    print("\n== Table 1 analogue: runtime vs tolerance/accepted ==")
    print(table)
    # C3: per-backend ms/run spread across tolerances must be small
    for backend in ("xla", "xla_fused"):
        ms = [v["time_per_run_ms"] for k, v in raw.items() if k.startswith(backend)]
        spread = (max(ms) - min(ms)) / max(ms)
        print(f"C3 [{backend}]: time/run spread across tolerances = {spread:.1%} "
              f"({'PASS (<25%)' if spread < 0.25 else 'FAIL'})")
        raw[f"{backend}_c3_spread"] = spread
    save_result("table1_runtime", {"rows": rows, "raw": raw})
    return raw


if __name__ == "__main__":
    run()
